"""The AST rules.  Each encodes an invariant a past PR paid for the hard
way (DESIGN.md §9 maps rule id -> invariant -> motivating PR).

Scoping is by repo-relative path prefix (``ctx.scope``); the fixture
corpus adopts a scope with the ``# repro-lint: scope=...`` pragma.
"""
from __future__ import annotations

import ast

from .engine import FileContext, rule

SRC = "src/repro/"
CONFIG_NAMES = {"cfg", "config", "approx_cfg", "approx_config", "error_cfg"}
# paged-KV data operands: block tables / page indices / sequence lengths
# are per-tick DATA (the paged engine's zero-retrace invariant) and must
# never become shapes, like the error config above
TABLE_NAMES = {"block_table", "block_tables", "tables", "page_idx",
               "page_table", "page_indices", "seq_len", "seq_lens",
               "cache_len"}
# speculative-decoding knobs: the draft config is traced DATA and the
# draft depth is a HOST loop count bounded by the static max_k — if
# either picks a shape or steers a Python branch in a traced body, the
# live (k, draft-cfg) sweep compiles one executable per cell (PR 9)
SPEC_NAMES = {"draft_cfg", "draft_config", "draft_k", "spec_k", "k_draft"}
# telemetry / per-class-budget knobs (PR 10): spike scores and class
# budget splits are host-side control signals that feed the SAME traced
# config knob — if one leaks into a shape or a traced branch, every
# telemetry reading mints a new executable.  Plain ``window`` stays off
# this list: in nn/ it is a STATIC sliding-window size that legitimately
# shapes buffers; the telemetry-window concern (unbounded sample
# buffers) is bounded-state's job via the ``push`` tick method.
TELEMETRY_NAMES = {"class_budgets", "class_shares", "budget_share",
                   "spike_score", "spike_level"}
SCALAR_PREFETCH = {"cfg_ref", "rows_ref", "xscale_ref", "bt_ref", "len_ref"}
LAX_HOFS = {"scan", "cond", "while_loop", "fori_loop", "switch", "map",
            "associative_scan"}
TRACED_DECOS = {"jit", "vmap", "grad", "value_and_grad", "when",
                "checkpoint", "remat", "custom_vjp", "shard_map"}


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def _attr_chain(node: ast.AST) -> list[str]:
    """['jax', 'lax', 'scan'] for jax.lax.scan; [] if not a name chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def _identifiers(node: ast.AST):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id
        elif isinstance(sub, ast.Attribute):
            yield sub.attr


def _bare_names(node: ast.AST, names: set[str], parents) -> list[ast.Name]:
    """Name nodes in `names` that are NOT the base of an attribute access
    (``cfg.n_heads`` reads a static config object, not the traced knob)."""
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in names:
            par = parents.get(sub)
            if isinstance(par, ast.Attribute) and par.value is sub:
                continue
            out.append(sub)
    return out


def _has_shapeish(node: ast.AST) -> bool:
    """Does the expression derive from static metadata (shape/ndim/len)?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in ("shape", "ndim"):
            return True
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                and sub.func.id == "len":
            return True
    return False


def _isinstance_guard_names(fn: ast.AST) -> set[str]:
    """Names tested with isinstance(x, ... jax.Array ...) anywhere in fn —
    the static/traced dual-API dispatch pattern: the Python-level read on
    the static branch is unreachable for traced values."""
    guarded: set[str] = set()
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                and sub.func.id == "isinstance" and len(sub.args) == 2 \
                and "Array" in list(_identifiers(sub.args[1])):
            guarded.update(n for n in _identifiers(sub.args[0]))
    return guarded


def _functions(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _enclosing_function(node: ast.AST, parents):
    while node is not None:
        node = parents.get(node)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return node
    return None


def _arg_names(fn) -> list[str]:
    a = fn.args
    names = [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


# ---------------------------------------------------------------------------
# trace-safety
# ---------------------------------------------------------------------------

def _traced_bodies(ctx: FileContext) -> list[ast.AST]:
    """Function/lambda nodes whose bodies run under a JAX trace:
    jit/vmap/grad/pl.when-decorated defs, callables handed to jax.lax
    control flow or pallas_call, and Pallas kernels (>= 2 ``*_ref``
    params)."""
    traced: list[ast.AST] = []
    by_name = {fn.name: fn for fn in _functions(ctx.tree)}

    def mark_callable(arg: ast.AST):
        if isinstance(arg, ast.Lambda):
            traced.append(arg)
        elif isinstance(arg, ast.Name) and arg.id in by_name:
            traced.append(by_name[arg.id])

    for fn in _functions(ctx.tree):
        for deco in fn.decorator_list:
            if set(_identifiers(deco)) & TRACED_DECOS:
                traced.append(fn)
                break
        else:
            ref_params = [n for n in _arg_names(fn) if n.endswith("_ref")]
            if len(ref_params) >= 2:
                traced.append(fn)          # pallas kernel by convention
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain:
            continue
        last, penult = chain[-1], (chain[-2] if len(chain) > 1 else "")
        if (last in LAX_HOFS and penult == "lax") \
                or last in ("jit", "vmap", "grad", "value_and_grad",
                            "pallas_call", "shard_map"):
            for arg in node.args:
                mark_callable(arg)
    return traced


@rule("trace-safety")
def trace_safety(ctx: FileContext):
    """No Python-level reads of traced values.

    (a) inside traced bodies: ``float()/int()/bool()`` on non-constant,
        non-shape-derived values, ``.item()``, and np conversions all
        force concretization — a trace-time crash at best, a silent
        host sync at worst;
    (b) anywhere in nn/kernels/core: the same conversions applied to a
        config-named value (the zero-retrace knob) — the exact read
        that would turn the runtime config back into a Python int and
        shatter the one-executable guarantee.  ``isinstance(x,
        jax.Array)``-guarded static branches are exempt (the dual
        static/traced API), as are the allowlisted host-side files.
    """
    if not ctx.in_scope(SRC):
        return
    conversions = {"float", "int", "bool"}
    np_converts = {"asarray", "array", "float32", "float64", "int32", "int64"}

    def flag_convert(call: ast.Call, why: str):
        yield ctx.finding(call, "trace-safety", why)

    traced = _traced_bodies(ctx)
    for body in traced:
        guarded = _isinstance_guard_names(body)
        for node in ast.walk(body):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item":
                yield ctx.finding(
                    node, "trace-safety",
                    ".item() in a traced body concretizes the tracer")
                continue
            chain = _attr_chain(node.func)
            is_builtin = chain and len(chain) == 1 \
                and chain[0] in conversions
            is_np = len(chain) == 2 and chain[0] == "np" \
                and chain[1] in np_converts
            if not (is_builtin or is_np) or not node.args:
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Constant) or _has_shapeish(arg):
                continue
            leaf_names = {n for n in _identifiers(arg)}
            if leaf_names & guarded:
                continue
            yield ctx.finding(
                node, "trace-safety",
                f"{'.'.join(chain)}() on a value inside a traced body — "
                "concretizes the tracer (host read under jit)")

    # (b) config-named values, name-based.  Scope: the modules a TRACED
    # config flows through (nn layers, kernels, the core quant/matmul
    # pipeline).  The host-side numpy oracles (power_model, controller,
    # approx_multiplier, hw_sim) and the calibration path (mlp_paper)
    # legitimately hold Python-int configs and are out of scope.
    if not ctx.in_scope(SRC + "nn/", SRC + "kernels/",
                        SRC + "core/approx_matmul.py",
                        SRC + "core/quantization.py"):
        return
    if ctx.in_scope(SRC + "nn/mlp_paper.py"):
        return                      # host-side calibration path (allowlist)
    for fn in _functions(ctx.tree):
        guarded = _isinstance_guard_names(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            is_conv = (len(chain) == 1 and chain[0] in conversions) or \
                (len(chain) == 2 and chain[0] == "np"
                 and chain[1] in np_converts)
            if not is_conv or not node.args:
                continue
            hits = _bare_names(node.args[0], CONFIG_NAMES, ctx.parents)
            hits = [h for h in hits if h.id not in guarded]
            if hits and not _has_shapeish(node.args[0]):
                yield ctx.finding(
                    node, "trace-safety",
                    f"Python-level read {'.'.join(chain)}({hits[0].id}...) "
                    "of the error config — the config is a traced runtime "
                    "value; reading it on the host breaks zero-retrace")


# ---------------------------------------------------------------------------
# cfg-shape (zero-retrace purity)
# ---------------------------------------------------------------------------

@rule("cfg-shape")
def cfg_shape(ctx: FileContext):
    """Config names must not flow into shape positions or Python control
    flow: a shape that depends on the config forces one executable per
    config value — exactly the retrace explosion the runtime knob
    exists to avoid.  The paged-KV table/length names (TABLE_NAMES) are
    held to the same bar: block tables and sequence lengths are data
    operands of the one compiled decode step, so a shape or traced
    branch derived from them retraces per occupancy instead.  The
    speculative knobs (SPEC_NAMES) likewise: the draft config is traced
    data and the draft depth a host loop count — only the static
    ``max_k`` window may shape anything (PR 9).  Telemetry/class-budget
    signals (TELEMETRY_NAMES) are held to the same bar: a spike score
    or budget split is a host control signal feeding the traced config
    DATA operand, never a shape or traced branch (PR 10)."""
    if not ctx.in_scope(SRC + "nn/", SRC + "kernels/", SRC + "serve/"):
        return
    shape_ctors = {"zeros", "ones", "full", "empty", "arange"}
    watched = CONFIG_NAMES | TABLE_NAMES | SPEC_NAMES | TELEMETRY_NAMES

    def problematic(test: ast.AST, names=watched) -> ast.Name | None:
        """First config Name in `test` that is not inside an isinstance
        call or an `is (not) None` comparison, with the whole test
        exempt when it isinstance-dispatches on that very name."""
        exempt_names: set[str] = set()
        for sub in ast.walk(test):
            if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                    and sub.func.id == "isinstance":
                exempt_names.update(n.id for n in _bare_names(
                    sub.args[0], names, ctx.parents))
        for name in _bare_names(test, names, ctx.parents):
            if name.id in exempt_names:
                continue
            par = ctx.parents.get(name)
            skip = False
            while par is not None:
                # branching on f(cfg) is branching on f's RESULT — if f
                # host-reads the value, the read is flagged inside f;
                # likewise `cfg is None` dispatches on the Python
                # default, not the traced value
                if isinstance(par, ast.Call):
                    skip = True
                    break
                if isinstance(par, ast.Compare) and all(
                        isinstance(op, (ast.Is, ast.IsNot))
                        for op in par.ops):
                    skip = True
                    break
                if par is test:
                    break
                par = ctx.parents.get(par)
            if not skip:
                return name
        return None

    def _kind(name: str) -> str:
        if name in CONFIG_NAMES:
            return "config"
        if name in SPEC_NAMES:
            return "speculative-knob"
        if name in TELEMETRY_NAMES:
            return "telemetry/class-budget"
        return "block-table/length"

    # serve/ is mostly host loop (branching on Python-int configs is its
    # job); there the branch check applies only inside traced bodies.
    branch_everywhere = ctx.in_scope(SRC + "nn/", SRC + "kernels/")
    traced_nodes: set[ast.AST] = set()
    if not branch_everywhere:
        for body in _traced_bodies(ctx):
            traced_nodes.update(ast.walk(body))
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.If, ast.While, ast.IfExp)) \
                and (branch_everywhere or node in traced_nodes):
            bad = problematic(node.test)
            if bad is not None:
                kind = _kind(bad.id)
                yield ctx.finding(
                    node.test, "cfg-shape",
                    f"Python branch on {kind} value '{bad.id}' — control "
                    "flow on a traced data operand retraces per value; use "
                    "jnp.where / lax.cond")
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain:
            continue
        shape_args: list[ast.AST] = []
        if chain[-1] in shape_ctors and len(chain) >= 2:
            shape_args = list(node.args) + [
                kw.value for kw in node.keywords if kw.arg == "shape"]
        elif chain[-1] in ("reshape", "broadcast_to"):
            shape_args = list(node.args[1:]) if chain[0] in ("jnp", "np") \
                else list(node.args)
        elif chain == ["range"]:
            shape_args = list(node.args)
        for arg in shape_args:
            if _has_shapeish(arg):
                continue     # jnp.shape(cfg)/cfg.shape is static metadata
            hits = _bare_names(arg, watched, ctx.parents)
            if hits:
                kind = _kind(hits[0].id)
                yield ctx.finding(
                    node, "cfg-shape",
                    f"{kind} value '{hits[0].id}' in a shape position of "
                    f"{'.'.join(chain)}() — shapes must be independent of "
                    "traced data operands (zero-retrace)")
                break


# ---------------------------------------------------------------------------
# single-rounding rescale
# ---------------------------------------------------------------------------

def _scale_leaves(node: ast.AST):
    """Multiplicative leaves of an expression: yields (leaf, kind) with
    kind in {'scale', 'other', 'neutral'}.  Descends through nested
    Mult chains and expand_left() wrappers."""
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        yield from _scale_leaves(node.left)
        yield from _scale_leaves(node.right)
        return
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        if chain and chain[-1] == "expand_left" and node.args:
            yield from _scale_leaves(node.args[0])
            return
    if isinstance(node, ast.Constant):
        yield node, "neutral"
        return
    if isinstance(node, ast.Name):
        kind = "scale" if ("scale" in node.id.lower()
                           or node.id in ("xs", "ws")) else "other"
    elif isinstance(node, ast.Attribute):
        kind = "scale" if "scale" in node.attr.lower() else "other"
    else:
        kind = "other"
    yield node, kind


def _kinds(node: ast.AST) -> set[str]:
    return {k for _, k in _scale_leaves(node)}


@rule("single-rounding")
def single_rounding(ctx: FileContext):
    """Dequant rescales must round the combined scale once:
    ``acc * (x_scale * w_scale)``.  The two-multiply chain
    ``(acc * x_scale) * w_scale`` is not association-stable under XLA —
    the simplifier regroups the scalar product, so differently-compiled
    paths diverge by 1 ulp and bit-identity dies (PR 3)."""
    if not ctx.in_scope(SRC):
        return
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.BinOp)
                and isinstance(node.op, ast.Mult)):
            continue
        par = ctx.parents.get(node)
        if isinstance(par, ast.BinOp) and isinstance(par.op, ast.Mult):
            continue                     # only report the outermost chain
        for side, other in ((node.left, node.right),
                            (node.right, node.left)):
            if not (isinstance(other, ast.BinOp)
                    and isinstance(other.op, ast.Mult)):
                continue
            side_kinds = _kinds(side)
            inner_kinds = _kinds(other)
            if side_kinds - {"neutral"} == {"scale"} \
                    and {"scale", "other"} <= inner_kinds:
                yield ctx.finding(
                    node, "single-rounding",
                    "two-multiply dequant chain '(acc * a) * scale' — XLA "
                    "reassociates it; round the combined scale once: "
                    "acc * (x_scale * w_scale)")
                break


# ---------------------------------------------------------------------------
# bounded-state
# ---------------------------------------------------------------------------

TICK_METHODS = {"step", "_step", "tick", "on_tick", "on_step", "record",
                "record_probe", "observe", "begin_tick", "arrivals",
                # telemetry windows (PR 10): every control signal now
                # flows through push/score per tick, so an unbounded
                # sample buffer there leaks at serving rate
                "push", "score"}


@rule("bounded-state")
def bounded_state(ctx: FileContext):
    """Serving state touched every engine tick must be bounded: an
    unbounded deque or a bare-list append on the tick path is a slow
    memory leak under continuous batching (PR 4/5)."""
    if not ctx.in_scope(SRC + "serve/"):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and _attr_chain(node.func) \
                and _attr_chain(node.func)[-1] == "deque":
            if not any(kw.arg == "maxlen" for kw in node.keywords):
                yield ctx.finding(
                    node, "bounded-state",
                    "deque() without maxlen in serve/ — serving state "
                    "must be bounded")
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        bare_lists: set[str] = set()
        for fn in cls.body:
            if isinstance(fn, ast.FunctionDef) and fn.name == "__init__":
                for stmt in ast.walk(fn):
                    tgt = None
                    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                        tgt, val = stmt.targets[0], stmt.value
                    elif isinstance(stmt, ast.AnnAssign) and stmt.value:
                        tgt, val = stmt.target, stmt.value
                    else:
                        continue
                    if isinstance(tgt, ast.Attribute) \
                            and isinstance(tgt.value, ast.Name) \
                            and tgt.value.id == "self" \
                            and isinstance(val, ast.List) and not val.elts:
                        bare_lists.add(tgt.attr)
        for fn in cls.body:
            if not isinstance(fn, ast.FunctionDef) \
                    or fn.name not in TICK_METHODS:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in ("append", "extend") \
                        and isinstance(node.func.value, ast.Attribute) \
                        and isinstance(node.func.value.value, ast.Name) \
                        and node.func.value.value.id == "self" \
                        and node.func.value.attr in bare_lists:
                    yield ctx.finding(
                        node, "bounded-state",
                        f"unbounded self.{node.func.value.attr}.append on "
                        f"the tick path ({cls.name}.{fn.name}) — use a "
                        "maxlen deque or drain it")


# ---------------------------------------------------------------------------
# injected-clock
# ---------------------------------------------------------------------------

@rule("injected-clock")
def injected_clock(ctx: FileContext):
    """Time must be injected in serve/ and dist/: a wall-clock read
    buried in scheduling logic makes ordering untestable (PR 4's
    scheduler bug).  The ONE allowed appearance is the default of a
    parameter (or dataclass field) named ``clock``."""
    if not ctx.in_scope(SRC + "serve/", SRC + "dist/"):
        return
    allowed: set[ast.AST] = set()

    def allow(node: ast.AST):
        if node is not None:
            allowed.update(ast.walk(node))

    for fn in ast.walk(ctx.tree):
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.Lambda)):
            a = fn.args
            pos = a.posonlyargs + a.args
            for name, default in zip(pos[len(pos) - len(a.defaults):],
                                     a.defaults):
                if name.arg == "clock":
                    allow(default)
            for name, default in zip(a.kwonlyargs, a.kw_defaults):
                if name.arg == "clock" and default is not None:
                    allow(default)
        elif isinstance(fn, ast.AnnAssign) and fn.value is not None:
            tgt = fn.target
            tname = tgt.id if isinstance(tgt, ast.Name) else \
                (tgt.attr if isinstance(tgt, ast.Attribute) else None)
            if tname == "clock":
                allow(fn.value)
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and node not in allowed \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "time" \
                and node.attr in ("time", "monotonic", "perf_counter",
                                  "time_ns", "monotonic_ns"):
            yield ctx.finding(
                node, "injected-clock",
                f"time.{node.attr} outside an injected-clock default — "
                "thread a clock parameter (like serve.Engine) so timing "
                "is testable")


# ---------------------------------------------------------------------------
# pallas-hygiene
# ---------------------------------------------------------------------------

@rule("pallas-hygiene")
def pallas_hygiene(ctx: FileContext):
    """Pallas kernel conventions: (a) BlockSpec index_map lambdas take
    grid indices and may close only over shape-derived locals — closing
    over a kernel-call parameter or calling into jnp re-traces per call
    and defeats block-map caching; (b) scalar-prefetch refs (cfg_ref /
    rows_ref / xscale_ref) come first in the kernel signature, matching
    PrefetchScalarGridSpec operand order."""
    if not ctx.in_scope(SRC + "kernels/"):
        return
    # (a) index_map lambdas inside BlockSpec(...) calls
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and _attr_chain(node.func)
                and _attr_chain(node.func)[-1] == "BlockSpec"):
            continue
        encl = _enclosing_function(node, ctx.parents)
        banned: set[str] = set()
        walk_up = encl
        while walk_up is not None:
            if not isinstance(walk_up, ast.Lambda):
                banned.update(_arg_names(walk_up))
            walk_up = _enclosing_function(walk_up, ctx.parents)
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if not isinstance(arg, ast.Lambda):
                continue
            own = set(_arg_names(arg))
            for sub in ast.walk(arg.body):
                if isinstance(sub, ast.Call):
                    fname = sub.func.id \
                        if isinstance(sub.func, ast.Name) else None
                    if fname not in own:
                        yield ctx.finding(
                            sub, "pallas-hygiene",
                            "index_map lambda calls a non-local — index "
                            "maps must be pure integer maps over grid "
                            "indices")
                elif isinstance(sub, ast.Name) and sub.id in banned \
                        and sub.id not in own:
                    yield ctx.finding(
                        sub, "pallas-hygiene",
                        f"index_map lambda closes over enclosing "
                        f"parameter '{sub.id}' — close over grid args / "
                        "shape-derived locals only")
    # (b) scalar-prefetch refs first
    for fn in _functions(ctx.tree):
        refs = [n for n in _arg_names(fn) if n.endswith("_ref")]
        if len(refs) < 2:
            continue
        seen_other = None
        for name in refs:
            if name in SCALAR_PREFETCH and seen_other is not None:
                yield ctx.finding(
                    fn, "pallas-hygiene",
                    f"scalar-prefetch operand '{name}' after '{seen_other}'"
                    f" in kernel {fn.name} — prefetch refs come first "
                    "(PrefetchScalarGridSpec order)")
                break
            if name not in SCALAR_PREFETCH:
                seen_other = name
