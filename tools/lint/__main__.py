"""Driver: ``python -m tools.lint [paths...] [--group ast|docs|retrace]``.

With no arguments runs everything CI runs: the AST rules over src/, the
docs-consistency group, and the runtime retrace sentinel.  With explicit
paths, lints just those files/dirs with the AST rules (the mode the
fixture tests use).  Exit code 1 on any finding.
"""
from __future__ import annotations

import argparse
import sys

from .engine import ROOT, lint_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m tools.lint")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src/ + docs + "
                         "retrace sentinel)")
    ap.add_argument("--group", action="append", default=None,
                    choices=["ast", "docs", "retrace"],
                    help="run only these groups (repeatable)")
    args = ap.parse_args(argv)

    if args.group is not None:
        groups = set(args.group)
    elif args.paths:
        groups = {"ast"}
    else:
        groups = {"ast", "docs", "retrace"}

    findings = []
    if "ast" in groups:
        paths = args.paths or [ROOT / "src"]
        findings += lint_paths(paths)
    if "docs" in groups:
        from . import docs_rules
        findings += docs_rules.run()
    if "retrace" in groups:
        from . import retrace
        findings += retrace.run()

    for f in findings:
        print(f)
    if findings:
        print(f"repro-lint: FAIL — {len(findings)} finding(s)")
        return 1
    print(f"repro-lint: OK ({', '.join(sorted(groups))})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
