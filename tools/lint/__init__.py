"""repro-lint: the repo's invariant checker (DESIGN.md §9).

AST rules over ``src/`` encode the invariants PRs 1-5 paid for in
debugging time — trace-safety, zero-retrace config purity, the
single-rounding rescale convention, bounded serving state, injected
clocks, Pallas kernel hygiene — plus a ``docs`` consistency group and a
runtime retrace sentinel.  One driver: ``python -m tools.lint``.
"""
from .engine import Finding, lint_file, lint_paths  # noqa: F401
