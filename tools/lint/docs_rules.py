"""The ``docs`` rule-group: docs-consistency checks (the former
tools/check_docs.py gate, folded into the one lint driver) plus
CHANGES.md PR-numbering and README BENCH-artifact verification.
"""
from __future__ import annotations

import re

from .engine import ROOT, Finding

SCAN_GLOBS = ("src/**/*.py", "tests/**/*.py", "benchmarks/**/*.py",
              "examples/**/*.py", "tools/**/*.py", "README.md",
              "ROADMAP.md", "DESIGN.md")


def run() -> list[Finding]:
    findings: list[Finding] = []

    def fail(path: str, line: int, msg: str):
        findings.append(Finding(path, line, "docs", msg))

    roadmap = (ROOT / "ROADMAP.md").read_text()
    readme = (ROOT / "README.md").read_text()
    design = (ROOT / "DESIGN.md").read_text()

    # 1. README carries ROADMAP's tier-1 verify command verbatim
    m = re.search(r"\*\*Tier-1 verify:\*\*\s*`([^`]+)`", roadmap)
    if not m:
        fail("ROADMAP.md", 1, "no '**Tier-1 verify:** `...`' line")
    elif f"\n{m.group(1)}\n" not in readme:
        fail("README.md", 1, "does not contain ROADMAP's tier-1 verify "
             f"command verbatim: {m.group(1)}")

    # 2. DESIGN.md § cross-references resolve
    sections = {int(n) for n in re.findall(r"^## §(\d+)", design, flags=re.M)}
    if not sections:
        fail("DESIGN.md", 1, "no '## §N' section headings")
    ref_re = re.compile(r"DESIGN(?:\.md)?\s*§(\d+)")
    for pattern in SCAN_GLOBS:
        for path in sorted(ROOT.glob(pattern)):
            text = path.read_text()
            for m in ref_re.finditer(text):
                if int(m.group(1)) not in sections:
                    ln = text.count("\n", 0, m.start()) + 1
                    fail(str(path.relative_to(ROOT)), ln,
                         f"dangling DESIGN.md §{m.group(1)} reference "
                         f"(existing: {sorted(sections)})")

    # 3. README names only BENCH artifacts a benchmark emits
    bench_src = (ROOT / "benchmarks" / "run.py").read_text() + \
        (ROOT / "benchmarks" / "sharded_decode.py").read_text()
    emitted = set(re.findall(r"BENCH_\w+\.json", bench_src))
    for name in sorted(set(re.findall(r"BENCH_\w+\.json", readme)) - emitted):
        fail("README.md", 1,
             f"references BENCH artifact no benchmark emits: {name}")

    # 4. CI keeps the tier-1 runtime budget gate: every PR adds tests,
    # so the suite only stays inside its wall-time budget if the gate
    # that fails CI past 1080 s cannot be silently dropped or loosened
    ci = ROOT / ".github" / "workflows" / "ci.yml"
    if not ci.exists():
        fail(".github/workflows/ci.yml", 1, "CI workflow missing")
    else:
        ci_text = ci.read_text()
        m = re.search(r'"\$wall"\s+-gt\s+(\d+)', ci_text)
        if not m:
            fail(str(ci.relative_to(ROOT)), 1,
                 "tier-1 wall-time gate ('$wall' -gt N) missing")
        elif int(m.group(1)) > 1080:
            ln = ci_text.count("\n", 0, m.start()) + 1
            fail(str(ci.relative_to(ROOT)), ln,
                 f"tier-1 runtime budget loosened past 1080s "
                 f"({m.group(1)}s) — trim tests instead")
        if "python -m pytest -x -q" not in ci_text:
            fail(str(ci.relative_to(ROOT)), 1,
                 "tier-1 pytest step missing from CI")

    # 5. CHANGES.md PR numbering is contiguous (1..max, each exactly once)
    changes = (ROOT / "CHANGES.md").read_text()
    prs = [int(n) for n in re.findall(r"^- PR (\d+):", changes, flags=re.M)]
    if not prs:
        fail("CHANGES.md", 1, "no '- PR N:' entries")
    elif sorted(prs) != list(range(1, max(prs) + 1)):
        fail("CHANGES.md", 1,
             f"PR numbering not contiguous 1..{max(prs)}: {sorted(prs)}")

    return findings
