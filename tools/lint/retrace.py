"""The ``retrace`` sentinel: a RUNTIME probe of the zero-retrace
invariant the AST rules can only approximate.

Jits the dense layer with a TRACED error config, runs it at several
config values, and asserts ONE executable served them all
(``_cache_size() == 1``).  Also asserts the config is live (different
configs give different outputs — a config optimized away would make the
cache check vacuously pass) and that tracing never tries to concretize
the config (ConcretizationTypeError).
"""
from __future__ import annotations

import sys

from .engine import ROOT, Finding

HERE = "tools/lint/retrace.py"


def run() -> list[Finding]:
    sys.path.insert(0, str(ROOT / "src"))
    try:
        import jax
        import jax.numpy as jnp
        from repro.core.quantization import quantize
        from repro.nn.layers import dense
    except Exception as e:  # pragma: no cover - broken env, not a lint hit
        return [Finding(HERE, 1, "retrace", f"sentinel could not import "
                        f"the model stack: {e!r}")]

    w = quantize(
        jax.random.normal(jax.random.PRNGKey(0), (16, 8)), axis=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))

    probe = jax.jit(lambda x, w, c: dense(x, w, approx_cfg=c))
    try:
        outs = [probe(x, w, jnp.asarray(c, jnp.int32)).block_until_ready()
                for c in (0, 7, 31)]
    except jax.errors.ConcretizationTypeError as e:
        return [Finding(HERE, 1, "retrace",
                        "tracing dense() concretized the traced config — "
                        f"a Python-level read is back: {e}")]

    findings = []
    n_compiles = probe._cache_size()
    if n_compiles != 1:
        findings.append(Finding(
            HERE, 1, "retrace",
            f"{n_compiles} executables for 3 config values — the error "
            "config leaked into a shape/branch position (zero-retrace "
            "broken; expected exactly 1 compile)"))
    if bool(jnp.array_equal(outs[0], outs[2])):
        findings.append(Finding(
            HERE, 1, "retrace",
            "config 0 and config 31 produced identical outputs — the "
            "traced config is dead in the jaxpr, so the cache check "
            "proves nothing"))
    return findings
