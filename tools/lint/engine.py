"""repro-lint core: file contexts, suppression parsing, rule registry.

Rules are plain functions ``rule(ctx) -> iterable[Finding]`` registered
with the ``@rule("rule-id")`` decorator.  Each rule guards on
``ctx.scope`` — the repo-relative posix path of the file, overridable in
out-of-tree fixtures with a ``# repro-lint: scope=src/repro/...`` pragma
so the test corpus can exercise path-scoped rules.

Suppressions are line-scoped comments:

    # repro-lint: disable=RULE — reason

on the offending line or the line directly above it.  The reason is
MANDATORY: a suppression without one does not suppress anything and is
itself reported (rule id ``suppression``) — tribal knowledge has to be
written down to be waived.
"""
from __future__ import annotations

import ast
import pathlib
import re
from dataclasses import dataclass
from typing import Callable, Iterable

ROOT = pathlib.Path(__file__).resolve().parents[2]

SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([\w,-]+)"
    r"(?:\s*(?:—|--|:)\s*(\S.*))?")
SCOPE_RE = re.compile(r"#\s*repro-lint:\s*scope=([\w/.-]+)")


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class FileContext:
    """One parsed source file + its pragmas, handed to every rule."""

    def __init__(self, path, text: str | None = None):
        self.path = pathlib.Path(path)
        self.text = self.path.read_text() if text is None else text
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(self.path))
        try:
            self.rel = self.path.resolve().relative_to(ROOT).as_posix()
        except ValueError:
            self.rel = self.path.as_posix()
        m = SCOPE_RE.search(self.text)
        self.scope = m.group(1) if m else self.rel
        self.suppressions: dict[int, tuple[set[str], str | None]] = {}
        for i, ln in enumerate(self.lines, 1):
            m = SUPPRESS_RE.search(ln)
            if m:
                ids = {r.strip() for r in m.group(1).split(",") if r.strip()}
                self.suppressions[i] = (ids, m.group(2))
        self._parents: dict[ast.AST, ast.AST] | None = None

    def in_scope(self, *prefixes: str) -> bool:
        return any(self.scope.startswith(p) for p in prefixes)

    @property
    def parents(self) -> dict[ast.AST, ast.AST]:
        if self._parents is None:
            self._parents = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents

    def finding(self, node: ast.AST, rule_id: str, message: str) -> Finding:
        return Finding(self.rel, getattr(node, "lineno", 0), rule_id, message)


Rule = Callable[[FileContext], Iterable[Finding]]
RULES: dict[str, Rule] = {}


def rule(rule_id: str):
    def deco(fn: Rule) -> Rule:
        RULES[rule_id] = fn
        return fn
    return deco


def lint_file(path, text: str | None = None,
              rules: set[str] | None = None) -> list[Finding]:
    """Run the (selected) AST rules over one file; apply suppressions."""
    from . import rules as _rules  # noqa: F401  (registers RULES on import)
    ctx = FileContext(path, text)
    raw: list[Finding] = []
    for rid, fn in RULES.items():
        if rules is None or rid in rules:
            raw.extend(fn(ctx))
    kept = []
    for f in raw:
        suppressed = False
        for ln in (f.line, f.line - 1):
            sup = ctx.suppressions.get(ln)
            if sup and f.rule in sup[0] and sup[1]:
                suppressed = True
                break
        if not suppressed:
            kept.append(f)
    for ln, (_ids, reason) in sorted(ctx.suppressions.items()):
        if not reason:
            kept.append(Finding(
                ctx.rel, ln, "suppression",
                "suppression without a reason — write "
                "'# repro-lint: disable=RULE — reason'"))
    return sorted(kept, key=lambda f: (f.line, f.rule))


def lint_paths(paths, rules: set[str] | None = None) -> list[Finding]:
    """Lint files / directories (directories recurse over ``*.py``)."""
    findings: list[Finding] = []
    for p in paths:
        p = pathlib.Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_file(f, rules=rules))
    return findings
