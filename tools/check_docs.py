#!/usr/bin/env python
"""Docs-consistency gate (CI): fail when the docs drift from the source
of truth.

  1. README's tier-1 verify command must be EXACTLY the one ROADMAP.md
     declares (the ROADMAP is the canonical copy).
  2. Every ``DESIGN.md §N`` cross-reference in the tree must point at a
     section heading that actually exists in DESIGN.md (the PR 3
     renumber left several dangling; this keeps them dead).
  3. README must reference only BENCH_*.json artifacts that a
     ``benchmarks/run.py`` entry actually emits.

Run from the repo root:  python tools/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
# CHANGES.md / ISSUE.md are historical logs, not living docs
SCAN_GLOBS = ("src/**/*.py", "tests/**/*.py", "benchmarks/**/*.py",
              "examples/**/*.py", "tools/**/*.py", "README.md",
              "ROADMAP.md", "DESIGN.md")


def fail(msg: str) -> None:
    print(f"check_docs: FAIL — {msg}")
    sys.exit(1)


def main() -> None:
    roadmap = (ROOT / "ROADMAP.md").read_text()
    readme = (ROOT / "README.md").read_text()
    design = (ROOT / "DESIGN.md").read_text()

    # 1. verify command: ROADMAP's "**Tier-1 verify:** `cmd`" line
    m = re.search(r"\*\*Tier-1 verify:\*\*\s*`([^`]+)`", roadmap)
    if not m:
        fail("ROADMAP.md has no '**Tier-1 verify:** `...`' line")
    verify_cmd = m.group(1)
    if f"\n{verify_cmd}\n" not in readme:
        fail(f"README.md does not contain ROADMAP's tier-1 verify "
             f"command verbatim:\n  {verify_cmd}")

    # 2. DESIGN.md § cross-references
    sections = {int(n) for n in re.findall(r"^## §(\d+)", design,
                                           flags=re.M)}
    if not sections:
        fail("DESIGN.md has no '## §N' section headings")
    bad = []
    # match variant spellings ("DESIGN §5") and line-wrapped refs
    # ("DESIGN.md\n§4") — both escaped the first version of this gate
    ref_re = re.compile(r"DESIGN(?:\.md)?\s*§(\d+)")
    for pattern in SCAN_GLOBS:
        for path in sorted(ROOT.glob(pattern)):
            text = path.read_text()
            for m in ref_re.finditer(text):
                if int(m.group(1)) not in sections:
                    ln = text.count("\n", 0, m.start()) + 1
                    bad.append(f"{path.relative_to(ROOT)}:{ln} "
                               f"-> §{m.group(1)}")
    if bad:
        fail("dangling DESIGN.md § references (existing sections: "
             f"{sorted(sections)}):\n  " + "\n  ".join(bad))

    # 3. README's BENCH artifacts are ones the harness emits
    bench_src = (ROOT / "benchmarks" / "run.py").read_text() + \
        (ROOT / "benchmarks" / "sharded_decode.py").read_text()
    emitted = set(re.findall(r"BENCH_\w+\.json", bench_src))
    missing = set(re.findall(r"BENCH_\w+\.json", readme)) - emitted
    if missing:
        fail(f"README references BENCH artifacts no benchmark emits: "
             f"{sorted(missing)}")

    print(f"check_docs: OK (verify command pinned, "
          f"{len(sections)} DESIGN sections, § refs clean, "
          f"{len(emitted)} BENCH artifacts)")


if __name__ == "__main__":
    main()
