#!/usr/bin/env python
"""Back-compat shim: the docs-consistency gate now lives in the unified
lint driver as the ``docs`` rule-group.  Equivalent invocation:

    python -m tools.lint --group docs
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from tools.lint.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--group", "docs"]))
